"""Fault-tolerant checkpointing with reshard-on-restore.

Rubick's reconfiguration mechanism is checkpoint-resume (paper Sec 5.2/6):
a reconfigured job saves a checkpoint, restarts with a new plan/allocation,
and restores — so restore must work onto a DIFFERENT mesh/plan than the one
that saved (elastic scaling).  Params/opt-state are saved as plain named
arrays; on restore each leaf is re-placed under the new shardings.

Layout:  <dir>/step_<n>/{arrays.npz, meta.json}   (atomic via tmp+rename)

``meta.json`` is the latest-checkpoint pointer (``list_steps`` keys on
its existence), so its write path is crash-safe: contents land in a tmp
file that is fsynced, atomically renamed into place, and the directory
rename that publishes the whole step is fsynced through the parent — a
crash mid-save can never leave a torn pointer, only the previous intact
checkpoint.  ``restore_cost_estimate`` prices a restart from real pytree
sizes with the same bandwidth model the simulator charges for simulated
failures (``memory.restore_seconds``).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from time import perf_counter
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    import jax.tree_util as jtu
    flat, _ = jtu.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            out[key + "::bf16"] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def _unflatten_into(template: Any, arrays: dict[str, np.ndarray]) -> Any:
    import jax.tree_util as jtu
    flat, treedef = jtu.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        if key + "::bf16" in arrays:
            arr = arrays[key + "::bf16"].view(jnp.bfloat16)
        elif key in arrays:
            arr = arrays[key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    return jtu.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_last: int = 3,
                 async_save: bool = True, recorder: Any | None = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        self.recorder = recorder       # flight recorder (repro.obs), opt-in
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, params: Any, opt_state: Any | None = None,
             meta: dict | None = None, block: bool = False) -> Path:
        """Atomic save; async by default so training overlaps the write."""
        self.wait()
        arrays = _flatten({"params": params,
                           **({"opt": opt_state} if opt_state is not None
                              else {})})
        meta = dict(meta or {})
        meta["step"] = step
        target = self.dir / f"step_{step:09d}"

        def _write():
            t0 = perf_counter()
            tmp = Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_"))
            np.savez(tmp / "arrays.npz", **arrays)
            # meta.json is the latest-checkpoint pointer: write-to-temp +
            # fsync + atomic rename so a crash mid-write can never leave
            # a torn (half-written) manifest that list_steps would trust
            mtmp = tmp / ".meta.json.tmp"
            with open(mtmp, "w") as f:
                f.write(json.dumps(meta))
                f.flush()
                os.fsync(f.fileno())
            os.replace(mtmp, tmp / "meta.json")
            if target.exists():
                shutil.rmtree(target)
            os.replace(tmp, target)
            # publish durably: the directory rename itself must survive a
            # power loss, or the pointer points at nothing after reboot
            fd = os.open(self.dir, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
            self._gc()
            if self.recorder is not None:
                nbytes = sum(a.nbytes for a in arrays.values())
                self.recorder.span("checkpoint-save", t0, perf_counter(),
                                   float(step), bytes=nbytes)

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
        return target

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @staticmethod
    def restore_cost_estimate(params: Any,
                              opt_state: Any | None = None) -> float:
        """Seconds a restart from this state would cost: total checkpoint
        bytes (every param/opt leaf) through the shared restore-bandwidth
        model — the same formula the simulator charges simulated failures
        via ``memory.restore_cost(profile=...)`` (there, sized
        analytically from the model profile instead of live arrays)."""
        from repro.core.memory import restore_cost
        nbytes = 0
        leaves = jax.tree.leaves({"params": params,
                                  **({"opt": opt_state}
                                     if opt_state is not None else {})})
        for leaf in leaves:
            nbytes += int(np.prod(np.shape(leaf))) \
                * np.dtype(getattr(leaf, "dtype", np.float32)).itemsize
        return restore_cost(nbytes=float(nbytes))

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "meta.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, params_template: Any, opt_template: Any | None = None,
                step: int | None = None,
                shardings: Any | None = None, opt_shardings: Any | None = None,
                ) -> tuple[Any, Any | None, dict]:
        """Restore onto possibly-different shardings (elastic restart)."""
        t0 = perf_counter()
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        arrays = dict(np.load(d / "arrays.npz"))
        meta = json.loads((d / "meta.json").read_text())
        params = _unflatten_into({"params": params_template}, arrays)["params"]
        if shardings is not None:
            params = jax.tree.map(
                lambda a, s: jax.device_put(a, s), params, shardings)
        opt = None
        if opt_template is not None:
            opt = _unflatten_into({"opt": opt_template}, arrays)["opt"]
            if opt_shardings is not None:
                opt = jax.tree.map(
                    lambda a, s: jax.device_put(a, s), opt, opt_shardings)
        if self.recorder is not None:
            self.recorder.span("checkpoint-restore", t0, perf_counter(),
                               float(step))
        return params, opt, meta
