"""repro — Rubick (reconfigurable-job cluster scheduling) on JAX/TPU.

Public surface:
    repro.configs          — 10 assigned architectures (+2 paper models)
    repro.models           — build(cfg) -> Model (loss/prefill/decode)
    repro.parallel         — ExecutionPlan + plan->GSPMD sharding compiler
    repro.core             — Rubick: perfmodel, scheduler, simulator, roofline
    repro.train / serve    — pjit train step, optimizers, checkpoints, engine
    repro.kernels          — Pallas TPU kernels (+ jnp oracles)
    repro.launch           — mesh / dryrun / train entry points
"""

__version__ = "1.0.0"
