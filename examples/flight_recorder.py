"""Flight-recorder walkthrough: trace a failure storm, then read the
story back out of the artifacts.

A `FlightRecorder` attached to a `Simulator` captures three channels:

  * **decision events** — every admission, reconfiguration, shrink
    (with victim + slope provenance), park/wake, capacity flip,
    eviction, checkpoint, pause, completion, and calibration refit,
    stamped with sim time and a cluster-state digest;
  * **time-series metrics** — GPU/CPU/host-mem utilization, queue
    depth, per-class goodput, violations, live capacity sampled at
    event boundaries;
  * **profiler spans** — wall-clock breakdown of scheduler-pass phases
    (admission, slope-order repair, victim walks, rollback), exported
    to Chrome-trace JSON (load it in Perfetto / chrome://tracing).

The JSONL decision log contains NO wall-clock values — two runs of the
same seed export byte-identical files — while the Perfetto file is
where all wall-clock timing lives.

Run:  PYTHONPATH=src python examples/flight_recorder.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import baselines, trace
from repro.core.cluster import Cluster
from repro.core.simulator import Simulator
from repro.obs import FlightRecorder, read_jsonl, write_jsonl, write_perfetto
from repro.obs.report import attribution, summary


def main() -> None:
    out = Path(__file__).parent / "out"
    out.mkdir(exist_ok=True)

    # -- 1. a contended cluster under a correlated failure storm -------
    cluster = Cluster(n_nodes=6)
    jobs = trace.generate(n_jobs=16, hours=4, seed=7, load_scale=2.0)
    cap = trace.failure_storm(6, 86400.0, seed=1, mtbf_s=86400.0,
                              storm=(5000.0, 20000.0, 40.0))

    # -- 2. attach a recorder and run ----------------------------------
    rec = FlightRecorder(meta={"example": "flight_recorder"})
    sched = baselines.make_rubick(pass_engine="incremental")
    sim = Simulator(cluster, sched, capacity=cap, recorder=rec)
    res = sim.run(jobs, max_time=4 * 86400.0)

    print(f"== run: {len(res.jcts)} jobs, makespan "
          f"{res.makespan / 3600:.2f} h, "
          f"{res.n_cap_events} capacity events ==")
    print(f"decision events: {dict(rec.counts)}")
    print(f"downtime: {res.total_paused_s / 3600:.3f} h total "
          f"({res.restore_paused_s / 3600:.3f} h restores)")
    worst = sorted(res.downtime_by_job.items(), key=lambda kv: -kv[1])[:3]
    for job, s in worst:
        print(f"  {job}: {s / 3600:.3f} h paused")

    # -- 3. export the three channels ----------------------------------
    jsonl = out / "storm.jsonl"
    perfetto = out / "storm.perfetto.json"
    write_jsonl(rec, jsonl)
    write_perfetto(rec, perfetto)
    print(f"\nwrote {jsonl} and {perfetto} "
          f"(open the latter in https://ui.perfetto.dev)")

    # -- 4. every eviction is attributable to its trigger --------------
    rows = attribution(read_jsonl(jsonl))
    print(f"\n== {len(rows)} evictions, "
          f"{sum(1 for r in rows if r['triggers'])} attributed ==")
    for r in rows[:5]:
        trig = ",".join(f"node{t['node']}:{t['kind']}"
                        for t in r["triggers"])
        print(f"  t={r['t']:8.0f}s {r['job']:<20} {r['outcome']:<7} "
              f"via {trig}")

    # -- 5. the same view the CLI renders ------------------------------
    print("\n== python -m repro.obs.report summary ==")
    summary(str(jsonl), perfetto=str(perfetto))


if __name__ == "__main__":
    main()
