"""Scheduler showdown: replay one trace under all seven policies and print
the paper-style comparison table, plus a live view of Rubick reconfiguring
a single job as the cluster drains (Fig 7 style).

Run:  PYTHONPATH=src python examples/scheduler_showdown.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import baselines, paper_models, trace
from repro.core.cluster import Cluster
from repro.core.oracle import AnalyticOracle, profiling_samples
from repro.core.perfmodel import fit
from repro.core.sensitivity import SensitivityCurve
from repro.core.simulator import Simulator


def main() -> None:
    print("== Fig 7-style: one LLaMA-2-7B job under shrinking resources ==")
    prof = paper_models.profile("llama2-7b")
    oracle = AnalyticOracle()
    k = fit(prof, profiling_samples(prof, oracle))
    curve = SensitivityCurve(prof, k, max_gpus=32)
    for g, label in [(32, "4 nodes × 8"), (16, "4 nodes × 4"),
                     (4, "1 node × 4"), (1, "1 GPU"), (1, "1 GPU, 2× CPU")]:
        cpus = 24 if label.endswith("2× CPU") else 12 * g
        pt = curve.best_plan_at_most(g, cpus)
        print(f"  {label:14s} -> {pt.plan.strategy if pt.plan else 'OOM':26s}"
              f" {pt.throughput:8.2f} samples/s")

    print("\n== Table 4-style: trace replay under every scheduler ==")
    jobs = trace.generate(n_jobs=40, hours=3, seed=1, load_scale=2.0)
    cluster = Cluster(n_nodes=8)
    cache: dict = {}
    print(f"  {'scheduler':10s} {'avgJCT(h)':>10s} {'p99(h)':>8s} "
          f"{'makespan(h)':>12s} {'reconfigs':>10s}")
    for name in ("rubick", "rubick-e", "rubick-r", "rubick-n",
                 "sia", "synergy", "antman"):
        sched = baselines.ALL[name]()
        res = Simulator(cluster, sched, fit_cache=cache).run(jobs)
        print(f"  {name:10s} {res.avg_jct/3600:10.2f} {res.p99_jct/3600:8.2f}"
              f" {res.makespan/3600:12.2f} {res.n_reconfig:10d}")


if __name__ == "__main__":
    main()
