"""Batched serving example: load a small model, prefill a batch of prompts,
greedy-decode continuations with the donated KV cache, and report
tokens/sec.  Exercises the same prefill/decode entry points the
``prefill_32k`` / ``decode_32k`` dry-run cells lower.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch gemma-2b]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    from repro import configs
    from repro.configs.base import ShapeConfig
    from repro.models import build
    from repro.serve.engine import ServeEngine

    cfg = configs.get_reduced(args.arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.gen + 1,
                         batch_size=args.batch)

    shape = ShapeConfig("serve", args.prompt_len, args.batch, "train")
    batch = model.dummy_batch(shape)
    print(f"arch={args.arch} (reduced)  batch={args.batch}  "
          f"prompt={args.prompt_len}  gen={args.gen}")

    t0 = time.time()
    out = engine.generate(batch, steps=args.gen)
    out.block_until_ready()
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:,.0f} tok/s incl. compile)")
    t0 = time.time()
    out = engine.generate(batch, steps=args.gen)
    out.block_until_ready()
    dt = time.time() - t0
    print(f"warm: {args.batch * args.gen / dt:,.0f} tok/s")
    for i in range(min(2, args.batch)):
        print(f"  sample {i}: {np.asarray(out[i])[:12]} ...")


if __name__ == "__main__":
    main()
