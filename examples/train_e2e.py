"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpointing, then demonstrate a Rubick RECONFIGURATION mid-run — the job
checkpoints, restarts with a different execution plan (GA×2 + gradient
checkpointing), and the loss trajectory continues unchanged (paper Fig 9).

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 300]
(defaults sized for a CPU laptop; ~100M params via a scaled gpt2 config)
"""

import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()

    import repro.configs.gpt2_1_5b as g
    from repro.core import costs
    from repro import configs as _c
    import repro.launch.train as T

    # ~100M-param model: 8L × 512, vocab 50257
    cfg = g.CONFIG.with_(n_layers=args.layers, d_model=args.d_model,
                         n_heads=8, n_kv_heads=8, d_ff=4 * args.d_model,
                         attn_chunk_q=64, attn_chunk_k=128, max_seq=1024)
    print(f"model: {costs.param_count(cfg)/1e6:.0f}M params")

    # monkey-patch the registry so the launcher sees our scaled config
    import repro.configs.base as base
    orig_get = base.get
    base.get = lambda name: cfg if name == "gpt2-100m" else orig_get(name)
    base._MODULE_FOR["gpt2-100m"] = "gpt2_1_5b"

    with tempfile.TemporaryDirectory() as d:
        half = args.steps // 2
        print(f"== phase 1: plan=DP (ZeRO-1) for {half} steps ==")
        T.train(arch="gpt2-100m", reduced=False, steps=half,
                batch=args.batch, seq=args.seq, lr=3e-4,
                plan_kw={"zero_stage": 1}, ckpt_dir=d, ckpt_every=50,
                log_every=20)
        print("== RECONFIGURE: checkpoint-resume with GA=2 + GC ==")
        out = T.train(arch="gpt2-100m", reduced=False, steps=args.steps,
                      batch=args.batch, seq=args.seq, lr=3e-4,
                      plan_kw={"zero_stage": 1, "ga_steps": 2, "gc": True},
                      ckpt_dir=d, ckpt_every=50, log_every=20)
        print(f"final loss {out['final_loss']:.4f} "
              f"(started ≈ ln(vocab) = 10.8)")


if __name__ == "__main__":
    main()
