"""Quickstart: Rubick in one file.

1. Profile a model (7 sample points, 3 with ZeRO-Offload) against the
   cluster oracle;
2. fit the Sec-4 performance model;
3. draw the resource-sensitivity curve and pick best plans;
4. schedule a small trace on a simulated 64-GPU cluster and compare
   against a plan-agnostic baseline.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import baselines, paper_models, trace
from repro.core.cluster import Cluster
from repro.core.oracle import AnalyticOracle, profiling_samples
from repro.core.perfmodel import fit, prediction_error, Alloc
from repro.core.sensitivity import SensitivityCurve
from repro.core.simulator import Simulator


def main() -> None:
    prof = paper_models.profile("llama2-7b")
    oracle = AnalyticOracle()

    print("== 1. profiling (paper: ~210 s on the real cluster) ==")
    samples = profiling_samples(prof, oracle)
    for plan, alloc, t in samples:
        print(f"   {plan.strategy:24s} {alloc.gpus:2d} GPUs -> {t:7.3f} s/iter")

    print("== 2. fitting the 7-parameter model ==")
    k = fit(prof, samples)
    avg, mx = prediction_error(prof, k, samples)
    print(f"   fit error on profiling set: avg {avg*100:.1f}%  max {mx*100:.1f}%")

    print("== 3. resource sensitivity curve (Fig 6) ==")
    curve = SensitivityCurve(prof, k, max_gpus=16)
    for g in (1, 2, 4, 8, 16):
        pt = curve.best_plan_at_most(g)
        print(f"   {g:2d} GPUs: best plan {pt.plan.strategy if pt.plan else '-':24s}"
              f" {pt.throughput:8.2f} samples/s")

    print("== 4. cluster scheduling (Table 4, miniature) ==")
    jobs = trace.generate(n_jobs=25, hours=2, seed=0, load_scale=2.0)
    cluster = Cluster(n_nodes=8)
    cache: dict = {}
    for name in ("rubick", "rubick-n", "synergy"):
        sim = Simulator(cluster, baselines.ALL[name](), fit_cache=cache)
        res = sim.run(jobs)
        print(f"   {name:9s} avg JCT {res.avg_jct/3600:5.2f} h   "
              f"makespan {res.makespan/3600:5.2f} h   "
              f"reconfigs {res.n_reconfig}")


if __name__ == "__main__":
    main()
