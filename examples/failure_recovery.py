"""Failure & elasticity walkthrough: kill a node under a 2-node LLaMA-2-7B
job and compare the two recovery policies, then lease a spot node and
revoke it with a graceful warning.

With a real model fit, minRes for the 16-GPU request is the full request
— so the kill-and-requeue baseline cannot re-admit the evicted job on
the surviving 8 GPUs and idles out the whole outage, while
shrink-instead-of-kill keeps the survivors training below minRes and
only pays the throughput gap.

Run:  PYTHONPATH=src python examples/failure_recovery.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import baselines, paper_models, trace
from repro.core.cluster import Cluster, Job
from repro.core.oracle import AnalyticOracle, profiling_samples
from repro.core.perfmodel import fit, fit_key
from repro.core.sensitivity import SensitivityCurve
from repro.core.simulator import Simulator
from repro.core.trace import CapacityEvent


def main() -> None:
    prof = paper_models.profile("llama2-7b")
    k = fit(prof, profiling_samples(prof, AnalyticOracle()))
    cache = {fit_key(prof): k}
    plan = SensitivityCurve(prof, k, max_gpus=16) \
        .best_plan_at_most(16, 192).plan

    print("== 16-GPU job spanning 2 nodes; node 1 dies 1000s..20000s ==")
    for mode in ("shrink", "kill"):
        job = Job(name="llama", profile=prof, submit=0.0,
                  target_iters=200_000.0, req_gpus=16, req_cpus=192,
                  orig_plan=plan, guaranteed=True, tenant="A")
        sched = baselines.make_rubick()
        sched.cfg.recovery = mode
        cap = [CapacityEvent(1000.0, 1, down=True),
               CapacityEvent(20000.0, 1, down=False, kind="recover")]
        sim = Simulator(Cluster(n_nodes=2), sched, fit_cache=dict(cache),
                        capacity=cap)
        res = sim.run([job], max_time=20 * 86400.0)
        print(f"  {mode:6s}: jct={res.jcts['llama']/3600:6.2f} h  "
              f"shrink-recoveries={res.n_shrink_recover}  "
              f"kill-requeues={res.n_kill_requeue}  "
              f"violations={res.guarantee_violations}")
    print("  (shrink keeps the survivors training below minRes and pays")
    print("   only the throughput gap; kill idles the whole outage, then")
    print("   restarts from the last checkpoint)")

    print("\n== Spot capacity: diurnal lease with 120s-warning revokes ==")
    cluster = Cluster(n_nodes=1)
    spot = cluster.add_spot_nodes(1)
    cap = trace.spot_churn(spot, 86400.0, seed=0, period_s=6 * 3600.0,
                           window_frac=0.5, jitter_s=600.0)
    jobs = trace.generate(n_jobs=6, hours=2, seed=2, load_scale=2.0)
    sim = Simulator(cluster, baselines.make_rubick(), fit_cache=dict(cache),
                    capacity=cap)
    res = sim.run(jobs)
    print(f"  capacity events={res.n_cap_events}  "
          f"shrink-recoveries={res.n_shrink_recover}  "
          f"kill-requeues={res.n_kill_requeue}  "
          f"avg JCT={res.avg_jct/3600:.2f} h")
    print("  (a graceful revoke checkpoints at the warning, so no work is")
    print("   lost; a surprise revoke rolls back to the last checkpoint)")


if __name__ == "__main__":
    main()
